"""Synthetic load test: Poisson arrivals replayed through the engine.

``make_trace`` draws a seeded arrival trace (exponential interarrivals at
``ServeSpec.rate`` req/s on the VIRTUAL clock, prompt/gen lengths mixed
uniformly in [len/2, len]); ``run_load_test`` replays it through

  1. a discarded warmup pass (pays XLA compilation — satellite of the
     old driver's tok/s bug: cold and steady wall numbers are reported
     separately, control metrics never include compile time),
  2. the continuous-batching engine,
  3. the static-batch baseline (gang admission) on the SAME trace with
     the SAME compiled functions,

and reports TTFT / per-token latency histograms (``obs.Histogram``
p50/p95/p99) plus throughput on both clocks. Virtual-clock numbers are
deterministic in (spec, seed) — CI asserts on those; wall-clock numbers
describe the machine the test ran on and are reported, never asserted.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.obs.metrics import Histogram
from repro.serve.scheduler import Request, ServeEngine, serve_fns


def make_trace(sv, vocab_size: int, seed: int = 0) -> list[Request]:
    """Seeded Poisson arrival trace with mixed prompt/gen lengths."""
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(sv.n_requests):
        t += float(rng.exponential(1.0 / sv.rate))
        plen = int(rng.integers(max(1, sv.prompt_len // 2),
                                sv.prompt_len + 1))
        gen = int(rng.integers(max(1, sv.gen // 2), sv.gen + 1))
        reqs.append(Request(
            rid=i,
            prompt=tuple(int(x) for x in
                         rng.integers(1, vocab_size, plen)),
            max_new=gen, arrival=t,
            deadline=None if sv.deadline is None else t + sv.deadline,
            stop_token=sv.stop_token))
    return reqs


def _latency_report(engine: ServeEngine,
                    completions) -> dict:
    """Histograms + throughput for one finished engine run."""
    ttft = Histogram("ttft")
    per_tok = Histogram("per_token")
    per_tok_wall = Histogram("per_token_wall")
    last: dict[int, tuple[float, float]] = {}
    for rid, _tok, tv, tw in engine.emissions:
        if rid in last:
            per_tok.observe(tv - last[rid][0])
            per_tok_wall.observe(tw - last[rid][1])
        last[rid] = (tv, tw)
    n_tok = n_drop = n_replay = 0
    for c in completions:
        if c.finish == "dropped":
            n_drop += 1
            continue
        n_tok += len(c.tokens)
        n_replay += c.replays
        if c.t_first is not None:
            ttft.observe(c.t_first - c.t_arrival)
    makespan = engine.now
    return {
        "ttft": ttft.summary(),                 # virtual seconds
        "per_token": per_tok.summary(),         # virtual seconds
        "per_token_wall": per_tok_wall.summary(),
        "tokens": n_tok,
        "dropped": n_drop,
        "replays": n_replay,
        "decode_steps": engine.n_steps,
        "makespan": makespan,                   # virtual seconds
        "throughput_tok_per_s": n_tok / makespan if makespan > 0 else None,
    }


def run_load_test(cfg, ctx, fs, segs, spec, *, dtype=None,
                  seed: int | None = None) -> dict:
    """Replay one trace through CB and the static baseline; see module
    docstring. Returns the BENCH_serve.json payload (sans provenance —
    the launch driver stamps that)."""
    import jax.numpy as jnp

    from repro.obs.provenance import provenance

    dtype = jnp.float32 if dtype is None else dtype
    sv = spec.serve
    seed = spec.seed if seed is None else seed
    fns = serve_fns(cfg, ctx, fs)

    def engine(policy):
        sp = dataclasses.replace(
            spec, serve=dataclasses.replace(sv, policy=policy))
        return ServeEngine(cfg, ctx, fs, segs, sp, dtype=dtype, fns=fns)

    def replay(eng):
        for r in make_trace(sv, cfg.vocab_size, seed):
            eng.submit(r)
        t0 = time.perf_counter()
        comps = eng.run()
        return comps, time.perf_counter() - t0

    # 1. warmup (discarded): pays compilation for every prefill bucket +
    #    the decode step, so the measured runs below are steady-state
    warm = engine("continuous")
    _, wall_cold = replay(warm)
    cold = _latency_report(warm, warm.completions.values())

    # 2. continuous batching, steady-state
    cb = engine("continuous")
    cb_comps, wall_cb = replay(cb)
    cont = _latency_report(cb, cb_comps)
    cont["wall_s"] = wall_cb

    # 3. static-batch baseline, same trace, same compiled fns
    st = engine("static")
    st_comps, wall_st = replay(st)
    static = _latency_report(st, st_comps)
    static["wall_s"] = wall_st

    tokens = {c.rid: c.tokens for c in cb_comps if c.finish != "dropped"}
    st_tokens = {c.rid: c.tokens for c in st_comps
                 if c.finish != "dropped"}
    both = set(tokens) & set(st_tokens)
    return {
        "provenance": provenance(spec),
        "trace": {"n_requests": sv.n_requests, "rate": sv.rate,
                  "seed": seed, "prompt_len": sv.prompt_len,
                  "gen": sv.gen, "deadline": sv.deadline},
        "continuous": cont,
        "static": static,
        # CB and static must emit identical sequences per request under
        # greedy decode — scheduling cannot change tokens (compared over
        # requests neither policy dropped)
        "tokens_match_static": all(tokens[r] == st_tokens[r]
                                   for r in both),
        "speedup_vs_static": (static["makespan"] / cont["makespan"]
                              if cont["makespan"] > 0 else None),
        "wall": {"cold_s": wall_cold,
                 "steady_s": wall_cb,
                 "tok_per_s_cold": (cold["tokens"] / wall_cold
                                    if wall_cold > 0 else None),
                 "tok_per_s_steady": (cont["tokens"] / wall_cb
                                      if wall_cb > 0 else None)},
    }
