"""Continuous-batching scheduler: admit/evict requests mid-generation.

The engine owns a fixed set of batch *slots* (``ServeSpec.batch``). Each
``step()`` (1) admits arrived requests into free slots — one B=1 prefill
per admission, written into the slot's cache pages — and (2) runs ONE
batched decode position across every active slot, so new prompts prefill
while co-resident requests keep decoding (continuous batching). The
``static`` policy is the baseline foil: gang admission only when ALL
slots are free, freed slots stay idle until the whole batch drains.

Two clocks:

* **virtual** (``self.now``, seconds) — advanced by the ``predict_admission``
  cost hook (ClusterSpec compute + link params, the ``tune/cost.py``
  pricing pattern). Poisson arrivals, deadlines and the CB-vs-static
  makespan comparison all live on this clock, so load tests are
  deterministic on any machine.
* **wall** (``time.perf_counter``) — measured per emission for the real
  latency histograms; never used for control decisions.

Admission is FIFO refined by deadline (earliest absolute deadline first
among arrived requests); a request whose predicted completion misses its
deadline — or whose sequence cannot fit the cache — is dropped LOUDLY
(stderr + ``serve.drop`` trace instant + a ``finish='dropped'``
completion). When the paged pool runs dry mid-decode, the youngest
active request is preempted: its blocks return to the free list and it
re-queues to replay from prompt + emitted tokens.

The prefill/decode convention (pinned bit-exact in tests/test_serve.py):
prefill runs on ``prefix[:-1]`` padded up to a whole number of blocks,
and ``prefix[-1]`` becomes the slot's *pending* token — the first decode
step consumes it at position ``len(prefix)-1`` through the same masked
decode path as every later token, so padded prefills emit exactly the
tokens an unpadded prefill would.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import sys
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.common import ArchConfig, ShardCtx
from repro.models.flatten import FlatSpec
from repro.obs import trace
from repro.serve.kvcache import (ContiguousKVCache, OutOfBlocks,
                                 PagedKVCache)
from repro.serve.streaming import stop_reason


@dataclasses.dataclass
class Request:
    """One generation request. ``prior`` carries tokens already emitted
    before a replay (failover / preemption) — the engine re-prefills
    ``prompt + prior`` and only generates the remaining budget."""

    rid: int
    prompt: tuple[int, ...]
    max_new: int
    arrival: float = 0.0                 # virtual seconds
    deadline: float | None = None        # absolute virtual completion bound
    stop_token: int | None = None
    prior: tuple[int, ...] = ()
    replays: int = 0

    def prefix(self) -> tuple[int, ...]:
        return tuple(self.prompt) + tuple(self.prior)


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list[int]                    # prior + newly generated
    finish: str                          # 'stop' | 'length' | 'dropped'
    t_arrival: float
    t_first: float | None                # virtual TTFT timestamp
    t_done: float
    replays: int = 0
    reason: str = ""                     # drop cause when finish='dropped'


def predict_admission(spec, prompt_tokens: int, gen_tokens: int) -> dict:
    """Default admission pricing from ClusterSpec compute/link params.

    Forward seconds per token position derive from the training step
    model (``compute_mean`` covers fwd+bwd of ``spec.seq`` positions;
    the forward share is ``1 - bwd_frac``); each generated token also
    pays the wire price of streaming its id over the cluster link
    (``LinkSpec.time`` — the same alpha+beta Eq. 1 pricing the tuner's
    CostModel charges). Returns ``{'t_prefill', 't_decode', 't_total'}``
    in virtual seconds.
    """
    cl = spec.cluster
    t_tok = cl.compute_mean * (1.0 - cl.bwd_frac) / max(1, spec.seq)
    t_dec = t_tok + cl.link_spec().time(4)  # one int32 id on the wire
    t_pre = prompt_tokens * t_tok
    return {"t_prefill": t_pre, "t_decode": t_dec,
            "t_total": t_pre + gen_tokens * t_dec}


def serve_fns(cfg: ArchConfig, ctx: ShardCtx, fs: FlatSpec) -> tuple:
    """One shared (jitted prefill, jitted decode) pair for the arch."""
    return (jax.jit(functools.partial(M.prefill_fn, cfg, ctx, fs)),
            jax.jit(functools.partial(M.decode_fn, cfg, ctx, fs)))


@dataclasses.dataclass
class _Slot:
    req: Request
    pos: int                             # valid cache length
    pending: int                         # next token to feed to decode
    emitted: list[int]
    t_first: float | None = None


class ServeEngine:
    """Continuous-batching engine over one model replica."""

    def __init__(self, cfg: ArchConfig, ctx: ShardCtx, fs: FlatSpec,
                 segs: Any, spec, *, dtype=jnp.float32,
                 predict: Callable = predict_admission,
                 cache: Any = None, fns: tuple | None = None):
        sv = spec.serve
        if cfg.family == "vlm":
            raise NotImplementedError(
                "continuous batching does not support static cross-KV "
                "(vlm) models")
        self.cfg, self.ctx, self.fs, self.segs = cfg, ctx, fs, segs
        self.spec, self.sv = spec, sv
        self.dtype = dtype
        self.now = 0.0
        self.wall0 = time.perf_counter()
        self.n_steps = 0
        self.predict = predict
        self.t_decode = predict(spec, 0, 1)["t_decode"]
        self.max_len = sv.resolved_max_len()
        if cache is None:
            cache = (PagedKVCache.from_cluster(cfg, ctx, spec.cluster, sv,
                                               dtype)
                     if sv.paged else
                     ContiguousKVCache(cfg, ctx, slots=sv.batch,
                                       block_size=sv.block_size,
                                       max_len=self.max_len, dtype=dtype))
        self.cache = cache
        self.slots: list[_Slot | None] = [None] * sv.batch
        self.queue: collections.deque[Request] = collections.deque()
        self.completions: dict[int, Completion] = {}
        self.emissions: list[tuple[int, int, float, float]] = []
        # jit caches live on the wrapped objects — pass one ``serve_fns``
        # pair to several engines (warmup / CB / static baseline) so they
        # share compilations instead of each paying XLA again
        self._prefill, self._decode = fns or serve_fns(cfg, ctx, fs)

    # -- introspection -----------------------------------------------------

    def pending(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def completion(self, rid: int) -> Completion | None:
        return self.completions.get(rid)

    def _wall(self) -> float:
        return time.perf_counter() - self.wall0

    # -- lifecycle ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _finish(self, slot_i: int, finish: str) -> None:
        s = self.slots[slot_i]
        self.cache.free(slot_i)
        self.slots[slot_i] = None
        self.completions[s.req.rid] = Completion(
            rid=s.req.rid, tokens=list(s.req.prior) + s.emitted,
            finish=finish, t_arrival=s.req.arrival, t_first=s.t_first,
            t_done=self.now, replays=s.req.replays)
        trace.current().instant("serve.finish", cat="serve",
                                args={"rid": s.req.rid, "finish": finish})

    def _drop(self, req: Request, reason: str) -> None:
        print(f"[serve] DROP rid={req.rid} ({reason}) at t={self.now:.3f}",
              file=sys.stderr)
        trace.current().instant("serve.drop", cat="serve",
                                args={"rid": req.rid, "reason": reason})
        self.completions[req.rid] = Completion(
            rid=req.rid, tokens=list(req.prior), finish="dropped",
            t_arrival=req.arrival, t_first=None, t_done=self.now,
            replays=req.replays, reason=reason)

    def _preempt_youngest(self) -> bool:
        """Evict the most recently arrived active request back to the
        queue (replaying later from prompt + emitted); False if no
        active request exists to evict."""
        cand = [(i, s) for i, s in enumerate(self.slots) if s is not None]
        if not cand:
            return False
        i, s = max(cand, key=lambda t: (t[1].req.arrival, t[1].req.rid))
        req = dataclasses.replace(
            s.req, prior=tuple(s.req.prior) + tuple(s.emitted),
            replays=s.req.replays + 1)
        self.cache.free(i)
        self.slots[i] = None
        self.queue.appendleft(req)
        trace.current().instant("serve.evict", cat="serve",
                                args={"rid": req.rid, "pos": s.pos})
        return True

    # -- admission ---------------------------------------------------------

    def _admission_order(self) -> list[Request]:
        """Arrived requests, earliest-deadline-first then FIFO."""
        arrived = [r for r in self.queue if r.arrival <= self.now]
        inf = float("inf")
        return sorted(arrived, key=lambda r: (
            inf if r.deadline is None else r.deadline, r.arrival, r.rid))

    def in_flight(self) -> list[Request]:
        """Replay-ready snapshots of the active requests (for failover)."""
        return [dataclasses.replace(
                    s.req, prior=tuple(s.req.prior) + tuple(s.emitted),
                    replays=s.req.replays + 1)
                for s in self.slots if s is not None]

    def _admit_one(self, req: Request, slot_i: int) -> bool:
        prefix = req.prefix()
        remaining = req.max_new - len(req.prior)
        if remaining <= 0:  # replay arrived with its budget already spent
            self.queue.remove(req)
            self.completions[req.rid] = Completion(
                rid=req.rid, tokens=list(req.prior), finish="length",
                t_arrival=req.arrival, t_first=None, t_done=self.now,
                replays=req.replays)
            return False
        if len(prefix) - 1 + remaining > self.max_len:
            self.queue.remove(req)
            self._drop(req, "too_long")
            return False
        est = self.predict(self.spec, len(prefix) - 1, remaining)
        if req.deadline is not None and \
                self.now + est["t_total"] > req.deadline:
            self.queue.remove(req)
            self._drop(req, "deadline")
            return False
        bs = self.sv.block_size
        P = len(prefix) - 1
        P_pad = -(-P // bs) * bs
        try:
            self.cache.ensure(slot_i, max(P_pad, 1))
        except OutOfBlocks:
            if not self.active():  # nothing running will ever free blocks
                self.queue.remove(req)
                self._drop(req, "oom")
            return False  # else stays queued; decode will free blocks
        self.queue.remove(req)
        if P:
            tokens = jnp.asarray(prefix[:P], jnp.int32)
            tokens = jnp.pad(tokens, (0, P_pad - P))[None, :]
            pre_cache = M.init_cache(self.cfg, self.ctx, 1, P_pad,
                                     self.dtype)
            with trace.current().span("serve.prefill", cat="serve",
                                      args={"rid": req.rid, "P": P}):
                _, pre_cache = self._prefill(
                    self.segs, {"tokens": tokens}, pre_cache)
            self.cache.write_prefill(slot_i, pre_cache, P)
        else:
            self.cache.write_prefill(
                slot_i, M.init_cache(self.cfg, self.ctx, 1, bs,
                                     self.dtype), 0)
        self.now += est["t_prefill"]
        self.slots[slot_i] = _Slot(req=req, pos=P, pending=prefix[-1],
                                   emitted=[])
        trace.current().instant("serve.admit", cat="serve",
                                args={"rid": req.rid, "slot": slot_i,
                                      "replays": req.replays})
        return True

    def _admit(self) -> None:
        if self.sv.policy == "static" and self.active():
            return  # gang scheduling: wait for the whole batch to drain
        for req in self._admission_order():
            if req.rid not in {r.rid for r in self.queue}:
                continue  # dropped/finished by an earlier admission pass
            free = [i for i, s in enumerate(self.slots) if s is None]
            if not free:
                break
            self._admit_one(req, free[0])

    # -- decode ------------------------------------------------------------

    def _ensure_decode_capacity(self) -> None:
        """Every active slot can place its next token; preempt the
        youngest active request (requeue-with-replay) while the pool is
        short. A single request larger than the whole pool is dropped."""
        while True:
            try:
                for i, s in enumerate(self.slots):
                    if s is not None:
                        self.cache.ensure(i, s.pos + 1)
                return
            except OutOfBlocks:
                if not self._preempt_youngest():
                    raise

    def step(self) -> list[tuple[int, int]]:
        """One engine step: admit, then one decode position across the
        active slots. Returns this step's ``(rid, token)`` emissions."""
        if not self.active() and self.queue and \
                not any(r.arrival <= self.now for r in self.queue):
            self.now = min(r.arrival for r in self.queue)  # fast-forward
        self._admit()
        if not self.active():
            return []
        self._ensure_decode_capacity()
        B = len(self.slots)
        toks = np.zeros((B, 1), np.int32)
        lens = np.zeros(B, np.int32)
        act = np.zeros(B, bool)
        for i, s in enumerate(self.slots):
            if s is not None:
                toks[i, 0], lens[i], act[i] = s.pending, s.pos, True
        with trace.current().span("serve.decode", cat="serve",
                                  args={"active": int(act.sum())}):
            out, new_cache = self._decode(
                self.segs, jnp.asarray(toks), jnp.asarray(lens),
                self.cache.gather())
            out = np.asarray(out)
        self.cache.scatter(new_cache, lens, act)
        self.now += self.t_decode
        self.n_steps += 1
        wall = self._wall()
        emitted: list[tuple[int, int]] = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            tok = int(out[i])
            s.emitted.append(tok)
            s.pending, s.pos = tok, s.pos + 1
            if s.t_first is None:
                s.t_first = self.now
            self.emissions.append((s.req.rid, tok, self.now, wall))
            emitted.append((s.req.rid, tok))
            why = stop_reason(len(s.emitted), len(s.req.prior),
                              s.req.max_new, s.req.stop_token, tok,
                              s.pos, self.max_len)
            if why is not None:
                self._finish(i, why)
        return emitted

    def run(self, max_steps: int = 100_000) -> list[Completion]:
        """Drive ``step`` until every submitted request completes."""
        for _ in range(max_steps):
            if not self.pending():
                break
            self.step()
        else:  # pragma: no cover
            raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return sorted(self.completions.values(), key=lambda c: c.rid)
