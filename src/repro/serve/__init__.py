"""repro.serve — continuous-batching serving engine (DESIGN.md §13).

Layers:

    kvcache   — paged / contiguous KV-cache backends over the model's
                ``init_cache`` pytree (free-list allocator, block tables)
    scheduler — continuous-batching engine: admit/evict mid-generation,
                deadline-aware admission priced by the cluster cost model
    streaming — per-request token generators + stop conditions
    replica   — multi-replica serving with heartbeat-driven failover

The engine is model-agnostic: anything exposing ``prefill_fn`` /
``decode_fn`` / ``init_cache`` (models/model.py) serves unchanged.
"""

from repro.serve.kvcache import (ContiguousKVCache, OutOfBlocks,  # noqa: F401
                                 PagedKVCache)
from repro.serve.scheduler import (Completion, Request,  # noqa: F401
                                   ServeEngine)
from repro.serve.streaming import stream_tokens  # noqa: F401
from repro.serve.replica import ReplicaSet  # noqa: F401
