"""Multi-replica serving with heartbeat-driven failover.

A ``ReplicaSet`` fronts N independent ``ServeEngine`` replicas (same
weights, separate caches). New requests route round-robin over the live
membership; each ``step_round`` steps every live replica once and beats
its heartbeat. A replica that stops beating (``kill`` in tests; a hung
process in life) is detected by ``runtime.heartbeat.HeartbeatMonitor``,
removed from the membership via ``runtime.elastic.replan`` (same
generation-bumped plan the trainer uses), and its in-flight + queued
requests re-route to survivors — each replays from prompt + the tokens
it already emitted, so under greedy decode the client-visible sequence
is identical to an uninterrupted run (pinned in tests/test_serve.py).
A replayed request past its deadline is dropped loudly instead.

The monitor runs on the replica set's own round clock (one tick per
``step_round``), so failover tests are deterministic — no wall-clock
sleeps.
"""

from __future__ import annotations

import sys

from repro.runtime import elastic
from repro.runtime.heartbeat import HeartbeatMonitor
from repro.serve.scheduler import Completion, Request, ServeEngine


class ReplicaSet:
    def __init__(self, engines: list[ServeEngine], *,
                 heartbeat_timeout: float = 2.0):
        assert engines, "need at least one replica"
        self.engines = dict(enumerate(engines))
        self.plan = elastic.initial_plan(len(engines))
        self.timeout = heartbeat_timeout
        self.round = 0
        self._killed: set[int] = set()
        self._rr = 0
        self.monitor = HeartbeatMonitor(
            list(self.engines), clock=lambda: float(self.round))
        # completions owned by no live engine: work finished on a now-dead
        # replica, plus failover deadline drops
        self._retired: dict[int, Completion] = {}

    # -- routing -----------------------------------------------------------

    def live(self) -> list[int]:
        return [r for r in self.plan.survivor_ids if r not in self._killed]

    def submit(self, req: Request) -> None:
        ids = self.live()
        rep = ids[self._rr % len(ids)]
        self._rr += 1
        self.engines[rep].submit(req)

    # -- failure injection / detection --------------------------------------

    def kill(self, rep: int) -> None:
        """Stop a replica's heartbeat (the test's failure injection)."""
        self._killed.add(rep)

    def _failover(self, dead: set[int]) -> None:
        self.plan = elastic.replan(self.plan, dead)
        for rep in sorted(dead):
            self.monitor.remove(rep)
            eng = self.engines.pop(rep)
            strays = eng.in_flight() + list(eng.queue)
            print(f"[serve] replica {rep} dead at round {self.round}: "
                  f"re-routing {len(strays)} request(s)", file=sys.stderr)
            for req in strays:
                if req.deadline is not None and \
                        min(e.now for e in self.engines.values()) \
                        > req.deadline:
                    print(f"[serve] DROP rid={req.rid} (deadline, "
                          f"failover)", file=sys.stderr)
                    self._retired[req.rid] = Completion(
                        rid=req.rid, tokens=list(req.prior),
                        finish="dropped", t_arrival=req.arrival,
                        t_first=None, t_done=float(self.round),
                        replays=req.replays, reason="deadline")
                    continue
                self.submit(req)
            # work that finished on the dead replica already streamed out
            self._retired.update(eng.completions)

    # -- driving -----------------------------------------------------------

    def step_round(self) -> None:
        """Step every live replica once, beat, then sweep for deaths."""
        self.round += 1
        for rep in self.live():
            self.engines[rep].step()
            self.monitor.beat(rep)
        dead = {r for r in self.monitor.dead(self.timeout)
                if r in self.engines}
        if dead:
            self._failover(dead)

    def pending(self) -> bool:
        return any(self.engines[r].pending() for r in self.live())

    def run(self, max_rounds: int = 100_000) -> list[Completion]:
        for _ in range(max_rounds):
            if not self.pending():
                break
            self.step_round()
        else:  # pragma: no cover
            raise RuntimeError(f"replica set did not drain in "
                               f"{max_rounds} rounds")
        out: dict[int, Completion] = dict(self._retired)
        for rep in self.live():
            for c in self.engines[rep].completions.values():
                out[c.rid] = c
        return sorted(out.values(), key=lambda c: c.rid)
