"""Paged and contiguous KV-cache backends for the serving engine.

Both backends manage the cache pytree of ``models.init_cache`` for a
fixed number of batch *slots* and expose one interface to the scheduler:

    ensure(slot, length)   — make positions [0, length) addressable
    write_prefill(slot, cache, length)  — install a B=1 prefill cache
    gather()               — contiguous (slots, T) view for decode_fn
    scatter(cache, kv_len, active)      — write back one decode step
    free(slot)             — release the slot's storage

``PagedKVCache`` stores KV in fixed-size blocks: each pool leaf is
(n, cnt, num_blocks, block_size, nkv, hd) and a logical block spans ALL
cycles/kinds at once (one shared block table + free list, physical index
reused in every kind's pool). ``gather`` assembles the per-slot block
lists into the contiguous layout decode expects; ``scatter`` writes back
only the block containing the position each row just wrote.

Bit-exactness (pinned in tests/test_serve.py): the gathered view equals
the true contiguous cache on every VALID position; positions >= kv_len
may differ (stale blocks vs stale dense rows) but ``decode_attention``
masks them with a finite -1e30 whose exp underflows to exactly 0.0, so
they cannot perturb the output bitwise.

Recurrent state kinds (rwkv/mamba — no time axis) are dense per-slot in
both backends; paging only applies to the KV kinds (``KV_CACHE_KINDS``).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.common import ArchConfig, ShardCtx

Array = jax.Array

_GiB = 1024 ** 3


class OutOfBlocks(RuntimeError):
    """Free list exhausted — the scheduler must evict or queue."""


def _leaf_list(tree: Any) -> list:
    return jax.tree_util.tree_leaves(tree)


class _CacheBase:
    """Shared slot/length bookkeeping + dense recurrent-state handling."""

    def __init__(self, cfg: ArchConfig, ctx: ShardCtx, *, slots: int,
                 block_size: int, max_len: int, dtype=jnp.bfloat16):
        assert max_len % block_size == 0, (max_len, block_size)
        self.cfg, self.ctx = cfg, ctx
        self.slots = slots
        self.block_size = block_size
        self.max_len = max_len
        self.max_blocks = max_len // block_size
        self.dtype = dtype
        self.lengths = np.zeros(slots, np.int64)  # addressable positions
        full = M.init_cache(cfg, ctx, slots, max_len, dtype)
        kv, state = M.split_cache(full)
        self._kv_shape = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), kv)
        self.state = state  # dense (n, cnt, slots, ...) leaves, batch ax 2

    def blocks_for(self, length: int) -> int:
        return -(-length // self.block_size)

    def _check_len(self, length: int) -> None:
        if length > self.max_len:
            raise OutOfBlocks(
                f"request length {length} exceeds cache max_len "
                f"{self.max_len}")

    def _write_state(self, slot: int, state_b1: dict) -> None:
        """Install a B=1 prefill state (or zeros) at ``slot`` (axis 2)."""
        self.state = jax.tree_util.tree_map(
            lambda dense, s1: dense.at[:, :, slot].set(
                s1[:, :, 0].astype(dense.dtype)),
            self.state, state_b1)

    def _zero_state(self, slot: int) -> None:
        self.state = jax.tree_util.tree_map(
            lambda dense: dense.at[:, :, slot].set(0), self.state)


class ContiguousKVCache(_CacheBase):
    """Dense reference backend: one (slots, max_len) cache, no paging.

    ``gather`` is the identity; ``scatter`` stores the step's cache back
    wholesale. Exists to pin the paged backend bit-exact and as the
    static-batch baseline's storage.
    """

    def __init__(self, cfg: ArchConfig, ctx: ShardCtx, *, slots: int,
                 block_size: int, max_len: int, dtype=jnp.bfloat16):
        super().__init__(cfg, ctx, slots=slots, block_size=block_size,
                         max_len=max_len, dtype=dtype)
        full = M.init_cache(cfg, ctx, slots, max_len, dtype)
        self.kv, _ = M.split_cache(full)

    @property
    def free_blocks(self) -> int:  # parity with PagedKVCache invariants
        return self.slots * self.max_blocks - sum(
            self.blocks_for(int(n)) for n in self.lengths)

    def ensure(self, slot: int, length: int) -> None:
        self._check_len(length)
        self.lengths[slot] = max(self.lengths[slot], length)

    def free(self, slot: int) -> None:
        self.lengths[slot] = 0

    def write_prefill(self, slot: int, cache_b1: dict, length: int) -> None:
        self.ensure(slot, length)
        kv1, st1 = M.split_cache(cache_b1)
        if length:
            self.kv = jax.tree_util.tree_map(
                lambda dense, c1: dense.at[:, :, slot, :length].set(
                    c1[:, :, 0, :length].astype(dense.dtype)),
                self.kv, kv1)
        self._write_state(slot, st1)

    def gather(self) -> dict:
        return M.merge_cache(self.kv, self.state)

    def scatter(self, cache: dict, kv_len: np.ndarray,
                active: np.ndarray) -> None:
        self.kv, self.state = M.split_cache(cache)


class PagedKVCache(_CacheBase):
    """Block-pooled KV storage with a free-list allocator.

    Physical block 0 is reserved and always zero — unallocated block-table
    entries gather from it, so the assembled view never reads stale pool
    memory outside a slot's own blocks.
    """

    def __init__(self, cfg: ArchConfig, ctx: ShardCtx, *, slots: int,
                 block_size: int, max_len: int, num_blocks: int,
                 dtype=jnp.bfloat16):
        super().__init__(cfg, ctx, slots=slots, block_size=block_size,
                         max_len=max_len, dtype=dtype)
        if num_blocks < 2:
            raise ValueError(f"num_blocks must be >= 2, got {num_blocks}")
        self.num_blocks = num_blocks
        self.pool = jax.tree_util.tree_map(
            lambda s: jnp.zeros(
                (s.shape[0], s.shape[1], num_blocks, self.block_size)
                + s.shape[4:], s.dtype),
            self._kv_shape)
        # block 0 reserved (always zero); LIFO free list for locality
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))
        self.tables = np.full((slots, self.max_blocks), -1, np.int32)

    # -- sizing ------------------------------------------------------------

    @staticmethod
    def block_bytes(cfg: ArchConfig, ctx: ShardCtx, block_size: int,
                    dtype=jnp.bfloat16) -> int:
        """Bytes one logical block occupies across ALL kinds' pools."""
        kv, _ = M.split_cache(
            M.cache_shapes(cfg, ctx, 1, block_size, dtype))
        return sum(l.size * l.dtype.itemsize for l in _leaf_list(kv))

    @classmethod
    def from_cluster(cls, cfg: ArchConfig, ctx: ShardCtx, cluster,
                     serve, dtype=jnp.bfloat16) -> "PagedKVCache":
        """Size the pool from ``ClusterSpec.mem_gb * ServeSpec.kv_frac``
        (or the explicit ``kv_blocks`` override), capped at the most the
        slot set can ever address (slots * max_blocks + zero block)."""
        max_len = serve.resolved_max_len()
        cap = serve.batch * (max_len // serve.block_size) + 1
        if serve.kv_blocks is not None:
            n = serve.kv_blocks
        else:
            per_block = cls.block_bytes(cfg, ctx, serve.block_size, dtype)
            budget = int(cluster.mem_gb * serve.kv_frac * _GiB)
            n = cap if per_block == 0 else budget // per_block
        return cls(cfg, ctx, slots=serve.batch, block_size=serve.block_size,
                   max_len=max_len, num_blocks=max(2, min(int(n), cap)),
                   dtype=dtype)

    # -- allocator ---------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def used_blocks(self, slot: int) -> int:
        return int((self.tables[slot] >= 0).sum())

    def ensure(self, slot: int, length: int) -> None:
        self._check_len(length)
        have = self.used_blocks(slot)
        need = self.blocks_for(length) - have
        if need > len(self._free):
            raise OutOfBlocks(
                f"need {need} blocks for slot {slot}, "
                f"{len(self._free)} free")
        for j in range(have, have + need):
            self.tables[slot, j] = self._free.pop()
        self.lengths[slot] = max(self.lengths[slot], length)

    def free(self, slot: int) -> None:
        phys = self.tables[slot]
        self._free.extend(int(p) for p in phys[phys >= 0])
        self.tables[slot] = -1
        self.lengths[slot] = 0

    # -- data movement -----------------------------------------------------

    def write_prefill(self, slot: int, cache_b1: dict, length: int) -> None:
        """Install a B=1 prefill cache: KV leaves are (n, cnt, 1, P, ...)
        with P a whole number of blocks <= max_len; positions beyond
        ``length`` in the last block are prefill padding (masked later)."""
        kv1, st1 = M.split_cache(cache_b1)
        if length and _leaf_list(kv1):  # pure-SSM archs have no KV kinds
            P = _leaf_list(kv1)[0].shape[3]
            assert P % self.block_size == 0 and length <= P, (length, P)
            self.ensure(slot, P)
            nb = P // self.block_size
            phys = jnp.asarray(self.tables[slot, :nb])
            self.pool = jax.tree_util.tree_map(
                lambda pool, c1: pool.at[:, :, phys].set(
                    c1[:, :, 0].reshape(
                        c1.shape[:2] + (nb, self.block_size) + c1.shape[4:]
                    ).astype(pool.dtype)),
                self.pool, kv1)
            self.lengths[slot] = length
        self._write_state(slot, st1)

    def gather(self) -> dict:
        """Assemble the contiguous (slots, max_len) view decode expects.

        Unallocated table entries read physical block 0 (always zero)."""
        tbl = jnp.asarray(np.where(self.tables < 0, 0, self.tables))

        def asm(pool):
            v = jnp.take(pool, tbl, axis=2)  # (n,cnt,slots,maxb,bs,...)
            return v.reshape(v.shape[:3] + (self.max_len,) + v.shape[5:])

        return M.merge_cache(
            jax.tree_util.tree_map(asm, self.pool), self.state)

    def scatter(self, cache: dict, kv_len: np.ndarray,
                active: np.ndarray) -> None:
        """Write back ONE decode step: row i of ``cache`` wrote position
        ``kv_len[i]``; copy just that position into its block. Inactive
        rows scatter to physical index ``num_blocks`` -> dropped."""
        kv, self.state = M.split_cache(cache)
        kv_len = np.asarray(kv_len)
        blk, off = kv_len // self.block_size, kv_len % self.block_size
        phys = np.where(active, self.tables[np.arange(self.slots), blk],
                        self.num_blocks).astype(np.int32)
        assert ((phys >= 0) | ~active).all(), "write to unallocated block"
        pj, oj = jnp.asarray(phys), jnp.asarray(off)

        def put(pool, leaf):
            # per-row slice at its own time index -> (n,cnt,slots,...)
            row = jax.vmap(
                lambda a, i: jax.lax.dynamic_index_in_dim(
                    a, i, axis=2, keepdims=False),
                in_axes=(2, 0), out_axes=2)(leaf, jnp.asarray(kv_len))
            return pool.at[:, :, pj, oj].set(
                row.astype(pool.dtype), mode="drop")

        self.pool = jax.tree_util.tree_map(put, self.pool, kv)
