"""Streaming drift detection over trace@2 step records (DESIGN.md §12).

The watchdog's front end: a deterministic per-phase change test that
turns the post-hoc overlap audit into an online signal. Each monitored
phase stream (compute / encode / comm / recover / t_step) learns a
FROZEN baseline from its first ``warmup`` untagged records, then runs a
two-sided Page-Hinkley test on the *relative* residual

    r_t = (x_t - mu) / max(|mu|, tiny)

so thresholds are scale-free: a sustained relative shift ``rho`` alarms
after at most ``ceil(threshold / (min(|rho|, clip) - delta))`` drifted
records (``detection_bound``), and a jitter-free stream (r_t == 0
exactly) can never alarm — the zero-false-positive guarantee
``benchmarks/drift_audit.py`` asserts.

Residuals are winsorized at ``clip`` before accumulating, so a single
transient spike (a replan stall, one straggler barrier) contributes at
most ``clip - delta`` and cannot alarm on its own; only sustained drift
crosses ``threshold``. The baseline is frozen — not EWMA-tracked —
after warmup, which is what makes the latency bound exact and keeps the
detector deterministic for a given record stream.

Alarms are attributed to the phase whose test fired, emitted as
structured ``drift.detected`` instants through the ambient
``trace.current()`` tracer, and returned as ``DriftEvent`` rows with the
estimated onset (the step of the last Page-Hinkley minimum = the last
step that still looked clean; drifted records are ``step > onset``) so
a calibration refit can window from there.
"""

from __future__ import annotations

import dataclasses
import math

from repro.obs import trace

#: Streams monitored by default. "stall" is deliberately excluded: its
#: clean baseline is ~0, so any transient (elastic replan, one-off
#: straggler) would explode the relative residual.
DEFAULT_PHASES = ("compute", "encode", "comm", "recover", "t_step")

_TINY = 1e-12


def detection_bound(rel: float, *, delta: float, threshold: float,
                    clip: float = 1.0) -> int:
    """Worst-case drifted records before a sustained relative shift of
    ``rel`` alarms. Infinite (returned as a large int) if the shift is
    inside the ``delta`` slack."""
    eff = min(abs(rel), clip) - delta
    if eff <= 0:
        return 1 << 30
    return int(math.ceil(threshold / eff))


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One alarm: which phase drifted, which way, and since when."""

    step: int           # step whose record fired the alarm
    phase: str          # compute | encode | comm | recover | t_step
    direction: str      # "up" (slower) | "down" (faster)
    value: float        # the firing record's phase time
    baseline: float     # frozen post-warmup mean
    rel: float          # (value - baseline) / baseline
    stat: float         # Page-Hinkley statistic at the alarm
    onset: int          # estimated LAST CLEAN step (the PH minimum);
                        # drifted records are those with step > onset

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class _PhaseStream:
    """Frozen-mean baseline + two-sided Page-Hinkley for one phase."""

    __slots__ = ("name", "delta", "threshold", "warmup", "clip",
                 "_n", "_sum", "mean", "_m_up", "_min_up", "_m_dn",
                 "_min_dn", "_min_step_up", "_min_step_dn")

    def __init__(self, name: str, *, delta: float, threshold: float,
                 warmup: int, clip: float):
        self.name = name
        self.delta = delta
        self.threshold = threshold
        self.warmup = warmup
        self.clip = clip
        self.reset()

    def reset(self) -> None:
        self._n = 0
        self._sum = 0.0
        self.mean = 0.0
        self._m_up = self._min_up = 0.0
        self._m_dn = self._min_dn = 0.0
        self._min_step_up = self._min_step_dn = -1

    def observe(self, x: float, step: int) -> "DriftEvent | None":
        if self._n < self.warmup:
            self._n += 1
            self._sum += x
            if self._n == self.warmup:
                self.mean = self._sum / self._n
            return None
        r = (x - self.mean) / max(abs(self.mean), _TINY)
        r = max(-self.clip, min(self.clip, r))
        # two one-sided CUSUM/Page-Hinkley accumulators on the clipped
        # relative residual; the running minimum marks the last clean step
        self._m_up += r - self.delta
        if self._m_up < self._min_up:
            self._min_up, self._min_step_up = self._m_up, step
        self._m_dn += -r - self.delta
        if self._m_dn < self._min_dn:
            self._min_dn, self._min_step_dn = self._m_dn, step
        ph_up = self._m_up - self._min_up
        ph_dn = self._m_dn - self._min_dn
        if max(ph_up, ph_dn) <= self.threshold:
            return None
        up = ph_up >= ph_dn
        onset = self._min_step_up if up else self._min_step_dn
        return DriftEvent(
            step=step, phase=self.name, direction="up" if up else "down",
            value=x, baseline=self.mean,
            rel=(x - self.mean) / max(abs(self.mean), _TINY),
            stat=ph_up if up else ph_dn,
            onset=onset if onset >= 0 else step)


class DriftDetector:
    """Deterministic streaming drift detector over trace@2 records.

    Feed ``observe(record)`` one per-step dict (the trace@2 ``records``
    row shape: ``t_step`` plus optional per-phase keys). Records tagged
    ``warmup`` are skipped entirely — they never enter the baseline.
    Returns the list of ``DriftEvent`` alarms this record fired (usually
    empty), each also emitted as a ``drift.detected`` instant through the
    ambient ``trace.current()`` tracer.

    ``reset()`` re-arms every stream (fresh baseline + fresh test) — the
    watchdog calls it after applying a re-plan, so the detector re-learns
    the post-plan regime instead of alarming on the plan change itself.
    """

    def __init__(self, *, delta: float = 0.1, threshold: float = 1.5,
                 warmup: int = 5, clip: float = 1.0,
                 phases: tuple = DEFAULT_PHASES):
        if warmup < 1:
            raise ValueError(f"warmup must be >= 1, got {warmup}")
        if threshold < 0:
            raise ValueError(f"threshold must be >= 0, got {threshold}")
        if not 0 < clip:
            raise ValueError(f"clip must be > 0, got {clip}")
        self.delta = delta
        self.threshold = threshold
        self.warmup = warmup
        self.clip = clip
        self.phases = tuple(phases)
        self.events: list[DriftEvent] = []
        self._streams = {
            ph: _PhaseStream(ph, delta=delta, threshold=threshold,
                             warmup=warmup, clip=clip)
            for ph in self.phases}

    def reset(self) -> None:
        for s in self._streams.values():
            s.reset()

    def baseline(self, phase: str) -> float | None:
        """Frozen baseline mean for ``phase`` (None while warming up)."""
        s = self._streams[phase]
        return s.mean if s._n >= s.warmup else None

    def observe(self, record: dict, *, step: int | None = None,
                ts: float | None = None) -> list[DriftEvent]:
        if record.get("warmup"):
            return []
        at = int(record.get("step", 0) if step is None else step)
        fired: list[DriftEvent] = []
        for ph in self.phases:
            x = record.get(ph)
            if x is None:
                continue
            ev = self._streams[ph].observe(float(x), at)
            if ev is None:
                continue
            fired.append(ev)
            self.events.append(ev)
            tr = trace.current()
            tr.instant(
                "drift.detected", cat="runtime", track="watchdog",
                ts=ts, args=ev.to_json())
            # one alarm consumed the evidence; restart this stream's test
            # (fresh baseline) so it re-learns the new regime
            self._streams[ph].reset()
        return fired
