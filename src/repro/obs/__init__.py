"""repro.obs — span tracing + metrics + provenance (DESIGN.md §10)."""

from repro.obs import trace
from repro.obs.drift import (DEFAULT_PHASES, DriftDetector, DriftEvent,
                             detection_bound)
from repro.obs.metrics import (TRACE2_SCHEMA, Metrics, dump, load_jsonl,
                               trace2_doc)
from repro.obs.provenance import provenance, runspec_hash
from repro.obs.trace import (NULL, PHASES, TRACE_SCHEMA, Tracer, current,
                             from_sim, validate)

__all__ = [
    "trace", "Tracer", "current", "from_sim", "validate", "NULL",
    "PHASES", "TRACE_SCHEMA", "TRACE2_SCHEMA", "Metrics", "trace2_doc",
    "dump", "load_jsonl", "provenance", "runspec_hash",
    "DEFAULT_PHASES", "DriftDetector", "DriftEvent", "detection_bound",
]
