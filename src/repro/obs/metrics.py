"""Counters / gauges / histograms + the ``repro.tune/trace@2`` schema.

``Metrics`` is a tiny in-process registry the train driver feeds per
step: wire bytes per bucket, compression ratio, EF residual norm,
exposed-vs-hidden comm time, and a step-time histogram. ``snapshot()``
serializes every instrument into the trace@2 document's ``metrics``
block.

trace@2 is a STRICT SUPERSET of trace@1 (DESIGN.md §8/§10): the
``records`` rows keep the exact trace@1 keys (step / t_step / rounds /
bytes / loss) and add warmup tags + quality metrics, and the document
adds ``provenance`` / ``metrics`` / ``predicted`` blocks —
``tune/calibrate.py`` consumes either schema unchanged (it reads only
the shared record keys, and drops rows tagged ``warmup``). A ``.jsonl``
path writes the streaming layout: header line (everything but records),
then one record per line — appendable mid-run, same document after
``load_jsonl``.
"""

from __future__ import annotations

import dataclasses
import json
import math

TRACE2_SCHEMA = "repro.tune/trace@2"


@dataclasses.dataclass
class Counter:
    name: str
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def to_json(self):
        return self.value


@dataclasses.dataclass
class Gauge:
    name: str
    value: float | None = None

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_json(self):
        return self.value


class Histogram:
    """Keeps raw observations (runs are short); summarizes on export."""

    def __init__(self, name: str):
        self.name = name
        self.values: list[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    def summary(self) -> dict:
        v = sorted(self.values)
        if not v:
            # full key set, all null: exported JSON stays schema-stable and
            # NaN/ZeroDivision-free when an instrument never observed
            return {"count": 0, "mean": None, "min": None, "max": None,
                    "p50": None, "p90": None, "p95": None, "p99": None}
        q = lambda p: v[min(len(v) - 1, int(math.ceil(p * len(v))) - 1)]  # noqa: E731
        return {"count": len(v), "mean": sum(v) / len(v),
                "min": v[0], "max": v[-1],
                "p50": q(0.50), "p90": q(0.90), "p95": q(0.95),
                "p99": q(0.99)}

    def to_json(self):
        return self.summary()


class Metrics:
    """Get-or-create instrument registry; one per capture run."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge(name))

    def histogram(self, name: str) -> Histogram:
        return self._hists.setdefault(name, Histogram(name))

    def snapshot(self) -> dict:
        return {
            "counters": {k: c.to_json()
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.to_json()
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.to_json()
                           for k, h in sorted(self._hists.items())},
        }


# ---------------------------------------------------------------------------
# trace@2 document
# ---------------------------------------------------------------------------


def trace2_doc(*, model: dict, records: list[dict],
               metrics: "Metrics | dict | None" = None,
               provenance: dict | None = None,
               predicted: dict | None = None) -> dict:
    """Assemble a trace@2 document. ``records`` rows must carry at least
    the trace@1 keys (step/t_step/rounds/bytes); extra keys ride along."""
    met = metrics.snapshot() if isinstance(metrics, Metrics) else metrics
    return {"schema": TRACE2_SCHEMA, "model": dict(model),
            "provenance": provenance, "metrics": met,
            "predicted": predicted, "records": list(records)}


def dump(doc: dict, path: str) -> None:
    """Write a trace document; ``.jsonl`` selects the streaming layout."""
    if path.endswith(".jsonl"):
        head = {k: v for k, v in doc.items() if k != "records"}
        with open(path, "w") as f:
            f.write(json.dumps(head) + "\n")
            for r in doc.get("records", []):
                f.write(json.dumps(r) + "\n")
    else:
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)


def load_jsonl(path: str) -> dict:
    """Reassemble a ``dump``-ed .jsonl trace into one document."""
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    if not lines:
        raise ValueError(f"empty trace file: {path}")
    doc = dict(lines[0])
    doc["records"] = lines[1:]
    return doc
