"""Low-overhead span tracing for the gs-SGD stack (DESIGN.md §10).

One ``Tracer`` collects nested spans (explicit begin/end or context
manager), instant events, and per-track ids, and exports Chrome/Perfetto
trace-event JSON (load the file at https://ui.perfetto.dev). The tracer is
AMBIENT: instrumented code — ``gs_sgd.exchange_interleaved`` /
``exchange_bucketed``, ``allreduce.tree_allreduce`` rounds, the
``runtime`` heartbeat/elastic/straggler policies — calls
``trace.current()``, which returns the active tracer or the module
``NULL`` singleton. The NULL tracer's span is a shared no-op object and
``sync`` is the identity, so with tracing disabled the instrumented
functions trace into *identical jaxprs* and identical step outputs
(pinned by tests/test_obs.py); no tracer is ever threaded through
signatures.

Span boundaries matter on an async backend: a span's ``sync(x)`` calls
``jax.block_until_ready`` on ``x`` (best-effort — a no-op on jax tracers
and non-arrays), so an *eagerly executed* instrumented step measures real
per-phase device time. Inside ``jax.jit`` spans cannot observe anything
(the python body runs once at trace time); the train driver therefore
runs one un-jitted PROBE step for phase attribution and wraps the jitted
steps in driver-level spans (see launch/train.py).

Span taxonomy — the ``cat`` field; the audit and the sim export share it:

    step       one whole training step (driver / sim timeline)
    probe      the eager instrumented step the phase spans live under
    forward    forward pass (chunked path; monolithic fwd+bwd = backward)
    backward   backward chunk VJPs / monolithic value_and_grad
    encode     per-bucket sketch encode (+ readiness instants)
    comm       per-bucket sketch all-reduce / per-tree-round sends
    recover    per-bucket decode + heavymix recovery
    optimizer  the segment-wise optimizer sweep
    runtime    heartbeat/elastic/straggler instants
    stall      sim-only: barrier + detection waits

``from_sim(result)`` renders a ``sim.cluster.SimResult`` into the same
schema, so a measured trace and a simulated one for the same RunSpec are
structurally identical (schema-equality is a tier-1 test).
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Callable, Iterable

TRACE_SCHEMA = "repro.obs/trace@1"

# Phase categories shared by the train probe, the sim export, and
# benchmarks/overlap_audit.py.
PHASES = ("forward", "backward", "encode", "comm", "recover")


# ---------------------------------------------------------------------------
# The disabled path: one shared no-op span, zero per-call allocation
# ---------------------------------------------------------------------------


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def sync(self, x):
        return x


_NULL_SPAN = _NullSpan()


class _NullTracer:
    """``current()`` when no tracer is active. Every method is a no-op."""

    enabled = False
    __slots__ = ()

    def span(self, name, *, cat="", track="main", args=None):
        return _NULL_SPAN

    def begin(self, name, *, cat="", track="main", args=None):
        return _NULL_SPAN

    def end(self, span):
        return None

    def instant(self, name, *, cat="", track="main", args=None, ts=None):
        return None


NULL = _NullTracer()

_CURRENT: "Tracer | None" = None


def current() -> "Tracer | _NullTracer":
    """The ambient tracer — ``NULL`` (all no-ops) unless one is active."""
    return _CURRENT if _CURRENT is not None else NULL


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


class Span:
    """One open span; close with ``tracer.end(span)`` or the with-block."""

    __slots__ = ("_tr", "name", "cat", "track", "args", "t0")

    def __init__(self, tr: "Tracer", name: str, cat: str, track: str,
                 args: dict | None):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.track = track
        self.args = args

    def sync(self, x):
        """Block until ``x``'s arrays are computed, then return it.

        Best-effort: inside a jit/vmap trace (or on non-array pytrees)
        this is the identity, so instrumented code stays jit-safe.
        """
        try:
            import jax
            jax.block_until_ready(x)
        except Exception:
            pass
        return x

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._tr.end(self)
        return False


class Tracer:
    """Collects spans/instants; clock-injectable for tests and the sim.

    Raw events keep times in SECONDS relative to ``epoch``;
    ``to_chrome``/``save`` convert to the trace-event µs convention.
    """

    enabled = True

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 epoch: float | None = None, pid: int = 0):
        self._clock = clock
        self.pid = pid
        self.epoch = clock() if epoch is None else epoch
        self.events: list[dict] = []
        self._stacks: dict[str, list[Span]] = {}

    # -- recording ----------------------------------------------------------

    def begin(self, name: str, *, cat: str = "", track: str = "main",
              args: dict | None = None) -> Span:
        sp = Span(self, name, cat, track, args)
        sp.t0 = self._clock() - self.epoch
        self._stacks.setdefault(track, []).append(sp)
        return sp

    def end(self, span: Span) -> None:
        t1 = self._clock() - self.epoch
        stack = self._stacks.get(span.track, [])
        if not stack or stack[-1] is not span:
            open_names = [s.name for s in stack]
            raise ValueError(
                f"span end out of order on track {span.track!r}: closing "
                f"{span.name!r} but the open stack is {open_names}")
        stack.pop()
        self.events.append({"ph": "X", "name": span.name, "cat": span.cat,
                            "track": span.track, "ts": span.t0,
                            "dur": t1 - span.t0, "args": span.args})

    def span(self, name: str, *, cat: str = "", track: str = "main",
             args: dict | None = None) -> Span:
        """``with tracer.span('encode/b0', cat='encode') as sp: ...``"""
        return self.begin(name, cat=cat, track=track, args=args)

    def instant(self, name: str, *, cat: str = "", track: str = "main",
                args: dict | None = None, ts: float | None = None) -> None:
        t = (self._clock() - self.epoch) if ts is None else ts
        self.events.append({"ph": "i", "name": name, "cat": cat,
                            "track": track, "ts": t, "args": args})

    def add_span(self, name: str, t0: float, t1: float, *, cat: str = "",
                 track: str = "main", args: dict | None = None) -> None:
        """Record a closed span directly (sim export path; times are in
        tracer-relative seconds)."""
        self.events.append({"ph": "X", "name": name, "cat": cat,
                            "track": track, "ts": t0, "dur": t1 - t0,
                            "args": args})

    def open_spans(self) -> list[str]:
        return [s.name for st in self._stacks.values() for s in st]

    # -- ambient activation -------------------------------------------------

    @contextlib.contextmanager
    def activate(self):
        """Install as the ambient ``current()`` tracer for the block."""
        global _CURRENT
        prev = _CURRENT
        _CURRENT = self
        try:
            yield self
        finally:
            _CURRENT = prev

    # -- export -------------------------------------------------------------

    def to_chrome(self, *, spec=None, provenance: dict | None = None,
                  source: str = "train") -> dict:
        """Chrome/Perfetto trace-event JSON with the run's identity
        embedded (schema / source / resolved spec / provenance), so a
        trace file alone is enough to re-price its schedule
        (benchmarks/overlap_audit.py)."""
        if self.open_spans():
            raise ValueError(
                f"cannot export with open spans: {self.open_spans()}")
        tids: dict[str, int] = {}
        out: list[dict] = []
        for e in sorted(self.events, key=lambda e: e["ts"]):
            track = e["track"]
            if track not in tids:
                tids[track] = len(tids)
                out.append({"ph": "M", "name": "thread_name", "pid": self.pid,
                            "tid": tids[track],
                            "args": {"name": track}})
            ev = {"name": e["name"], "cat": e["cat"] or "default",
                  "ph": e["ph"], "ts": e["ts"] * 1e6, "pid": self.pid,
                  "tid": tids[track], "args": e.get("args") or {}}
            if e["ph"] == "X":
                ev["dur"] = e["dur"] * 1e6
            else:
                ev["s"] = "t"
            out.append(ev)
        spec_doc = (spec.to_json() if hasattr(spec, "to_json") else spec)
        return {"schema": TRACE_SCHEMA, "source": source,
                "spec": spec_doc, "provenance": provenance,
                "displayTimeUnit": "ms", "traceEvents": out}

    def save(self, path: str, *, spec=None, provenance: dict | None = None,
             source: str = "train") -> dict:
        doc = self.to_chrome(spec=spec, provenance=provenance, source=source)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return doc


# ---------------------------------------------------------------------------
# Validation + chrome-doc helpers (shared by tests and overlap_audit)
# ---------------------------------------------------------------------------


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != TRACE_SCHEMA:
        raise ValueError(f"not a {TRACE_SCHEMA} document: "
                         f"schema={doc.get('schema')!r}")
    return doc


def _norm_events(doc_or_events) -> list[dict]:
    if isinstance(doc_or_events, Tracer):
        return doc_or_events.events
    if isinstance(doc_or_events, dict):
        return doc_or_events["traceEvents"]
    return list(doc_or_events)


def validate(doc_or_events) -> int:
    """Check span well-formedness; returns the number of spans checked.

    Within each track, "X" spans must be properly nested: any two either
    disjoint or one inside the other (a small relative epsilon absorbs
    float µs rounding). Raises ValueError on overlap. Begin/end pairing
    is enforced at record time (``Tracer.end``) and at export
    (``to_chrome`` refuses open spans), so a serialized doc that loads is
    pair-complete by construction.
    """
    by_track: dict[Any, list[tuple[float, float, str]]] = {}
    for e in _norm_events(doc_or_events):
        if e.get("ph") != "X":
            continue
        key = (e.get("pid"), e["tid"]) if "tid" in e else e.get("track")
        by_track.setdefault(key, []).append(
            (float(e["ts"]), float(e["ts"]) + float(e["dur"]), e["name"]))
    n = 0
    for key, spans in by_track.items():
        spans.sort(key=lambda s: (s[0], -(s[1] - s[0])))
        stack: list[tuple[float, float, str]] = []
        for t0, t1, name in spans:
            eps = 1e-6 * max(1.0, abs(t1))
            while stack and stack[-1][1] <= t0 + eps:
                stack.pop()
            if stack and t1 > stack[-1][1] + eps:
                raise ValueError(
                    f"track {key!r}: span {name!r} [{t0}, {t1}] overlaps "
                    f"{stack[-1][2]!r} [{stack[-1][0]}, {stack[-1][1]}] "
                    "without nesting")
            stack.append((t0, t1, name))
            n += 1
    return n


def spans(doc: dict, cat: str | None = None,
          name_prefix: str | None = None) -> list[dict]:
    """"X" events of a chrome doc, ts/dur converted back to seconds."""
    out = []
    for e in doc["traceEvents"]:
        if e.get("ph") != "X":
            continue
        if cat is not None and e.get("cat") != cat:
            continue
        if name_prefix is not None and not e["name"].startswith(name_prefix):
            continue
        out.append({**e, "ts": e["ts"] / 1e6, "dur": e["dur"] / 1e6})
    return out


def instants(doc: dict, name: str | None = None) -> list[dict]:
    return [{**e, "ts": e["ts"] / 1e6} for e in doc["traceEvents"]
            if e.get("ph") == "i"
            and (name is None or e["name"] == name)]


def phase_totals(doc: dict) -> dict[str, float]:
    """Total seconds per span category."""
    out: dict[str, float] = {}
    for e in spans(doc):
        out[e["cat"]] = out.get(e["cat"], 0.0) + e["dur"]
    return out


def bucket_durations(doc: dict, cat: str, prefix: str) -> list[float]:
    """Per-bucket stage durations from '<prefix>{i}'-named spans, in
    bucket order (e.g. cat='comm', prefix='allreduce/b')."""
    got: dict[int, float] = {}
    for e in spans(doc, cat=cat, name_prefix=prefix):
        try:
            i = int(e["name"][len(prefix):])
        except ValueError:
            continue
        got[i] = got.get(i, 0.0) + e["dur"]
    return [got[i] for i in sorted(got)]


# ---------------------------------------------------------------------------
# Sim timeline -> the same span schema
# ---------------------------------------------------------------------------


def from_sim(result) -> Tracer:
    """Render a ``sim.cluster.SimResult`` into a Tracer.

    Each ``StepRecord`` becomes a cat='step' umbrella span with
    sequential forward / backward / stall / encode / comm / recover
    children (compute split by the config's ``bwd_frac``); replans and
    straggler drops become cat='runtime' instants — the exact shape the
    train driver emits, so sim and measured traces diff structurally.
    Duck-typed on the result object: no sim import, no cycle.
    """
    cfg = result.config
    tr = Tracer(epoch=0.0)
    track = "cluster"
    for r in result.records:
        t0 = r.t_start
        tr.add_span(f"step{r.step}", t0, t0 + r.total, cat="step",
                    track=track,
                    args={"step": r.step, "warmup": False, "p": r.p,
                          "generation": r.generation, "t_step": r.total})
        cur = t0
        parts = (("forward", "forward", r.compute * (1.0 - cfg.bwd_frac)),
                 ("backward", "backward", r.compute * cfg.bwd_frac),
                 ("stall", "stall", r.stall),
                 ("encode", "encode", r.encode),
                 ("comm", "comm", r.comm),
                 ("recover", "recover", r.recover))
        for name, cat, dur in parts:
            if dur > 0.0:
                tr.add_span(name, cur, cur + dur, cat=cat, track=track,
                            args={"step": r.step})
            cur += dur
        for w in r.dropped:
            tr.instant("straggler.drop", cat="runtime", track=track,
                       ts=t0 + r.compute + r.stall,
                       args={"worker": int(w), "step": r.step})
    for rp in result.replans:
        tr.instant("elastic.replan", cat="runtime", track=track,
                   ts=rp["time"],
                   args={k: rp.get(k) for k in
                         ("step", "generation", "p", "failed", "joined",
                          "lr_scale")})
    for w in getattr(result, "watch", None) or []:
        tr.instant(w.get("kind", "watch"), cat="runtime", track=track,
                   ts=w.get("time") or 0.0,
                   args={k: v for k, v in w.items()
                         if k not in ("kind", "time")})
    return tr
