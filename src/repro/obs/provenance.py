"""One provenance stamp for every artifact (trace@2, TunePlan, BENCH_*).

``provenance(spec)`` answers "what produced this file": jax version +
backend/device kind, hostname/platform, the repo git revision, and the
sha256 of the resolved ``RunSpec`` JSON — so two artifacts are comparable
iff their spec hashes match, regardless of which CLI wrote them. Every
field is best-effort (``None`` rather than raising) so artifact writing
never fails on an exotic host.
"""

from __future__ import annotations

import hashlib
import json
import os
import platform
import socket
import subprocess

SCHEMA = "repro.obs/provenance@1"

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def runspec_hash(spec) -> str:
    """sha256 of the canonical resolved-spec JSON (sorted keys)."""
    doc = spec.to_json() if hasattr(spec, "to_json") else spec
    blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _git_rev() -> str | None:
    try:
        out = subprocess.run(
            ["git", "-C", _REPO_ROOT, "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5)
        return out.stdout.strip() or None
    except Exception:
        return None


def provenance(spec=None) -> dict:
    out: dict = {"schema": SCHEMA}
    try:
        import jax
        out["jax"] = jax.__version__
        out["backend"] = jax.default_backend()
        devs = jax.devices()
        out["device_kind"] = devs[0].device_kind if devs else None
        out["device_count"] = len(devs)
    except Exception:
        out.update(jax=None, backend=None, device_kind=None,
                   device_count=None)
    try:
        out["hostname"] = socket.gethostname()
    except Exception:
        out["hostname"] = None
    out["platform"] = platform.platform()
    out["python"] = platform.python_version()
    out["git_rev"] = _git_rev()
    if spec is not None:
        out["runspec_sha256"] = runspec_hash(spec)
    return out
